"""Span tracing: nested context managers over a ring-buffered trace log.

``span("decode_search")`` is the workhorse: when the layer is armed it
records a {name, start, wall duration, nesting depth, thread} event into
a bounded ring and observes the duration into the ``span_ms`` histogram
(labelled by span name).  When disarmed, ``span()`` returns a shared
no-op singleton -- no allocation, no clock read, no lock.

Device time is strictly opt-in: ``sp.fence(x)`` stores a jax array to
``block_until_ready`` at span exit, and the fence only fires when
tracing is ON, so instrumentation can never add a host sync to an
uninstrumented run (the sync_audit ratchet stays flat).

``now()`` is the sanctioned raw clock for code that needs a timestamp
across scopes; the ``obs-timers`` idiom-lint rule steers the rest of
``src/repro`` here instead of bare ``time.perf_counter()``.
"""

from __future__ import annotations

import contextlib
import threading
import time
from collections import deque

from . import metrics as _m

__all__ = [
    "NULL_SPAN",
    "Span",
    "Timer",
    "clear",
    "event",
    "events",
    "now",
    "profile",
    "span",
    "timer",
]

TRACE_CAPACITY = 4096
_RING: deque = deque(maxlen=TRACE_CAPACITY)
_EPOCH = time.perf_counter()
_TLS = threading.local()


def now() -> float:
    """Monotonic wall clock (seconds); the lint-blessed perf_counter alias."""
    return time.perf_counter()


def events() -> list:
    """Snapshot of the trace ring, oldest first."""
    return list(_RING)


def clear() -> None:
    _RING.clear()


def event(name: str, **fields) -> None:
    """Record a discrete event (health transition, failover, ...) iff armed."""
    if _m.enabled():
        rec = {"kind": "event", "name": name, "t_s": now() - _EPOCH}
        rec.update(fields)
        _RING.append(rec)


class Span:
    """Armed span: wall time always, device time via opt-in fence()."""

    __slots__ = ("name", "labels", "_t0", "_depth", "_fence")

    def __init__(self, name: str, labels: dict):
        self.name = name
        self.labels = labels
        self._fence = None

    def fence(self, x) -> None:
        """Block on ``x`` at span exit so the span covers device time.
        Only reachable when tracing is ON -- never fences a cold run."""
        self._fence = x

    def __enter__(self):
        depth = getattr(_TLS, "depth", 0)
        _TLS.depth = depth + 1
        self._depth = depth
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, exc_type, exc, tb):
        dev_ms = None
        if self._fence is not None:
            t_fence = time.perf_counter()
            try:
                import jax

                jax.block_until_ready(self._fence)
            except Exception:
                pass
            dev_ms = (time.perf_counter() - t_fence) * 1e3
            self._fence = None
        t1 = time.perf_counter()
        _TLS.depth = self._depth
        dur_ms = (t1 - self._t0) * 1e3
        rec = {
            "kind": "span",
            "name": self.name,
            "start_s": self._t0 - _EPOCH,
            "dur_ms": dur_ms,
            "depth": self._depth,
            "thread": threading.current_thread().name,
        }
        if dev_ms is not None:
            rec["fence_ms"] = dev_ms
        if self.labels:
            rec.update(self.labels)
        _RING.append(rec)
        labels = {"span": self.name, **self.labels}
        _m.REGISTRY.histogram("span_ms", **labels).observe(dur_ms)
        return False


class _NullSpan:
    """Disarmed singleton: every method is a constant no-op."""

    __slots__ = ()

    def fence(self, x) -> None:
        pass

    def __enter__(self):
        return self

    def __exit__(self, exc_type, exc, tb):
        return False


NULL_SPAN = _NullSpan()


def span(name: str, **labels):
    """Open a trace span; returns the shared no-op singleton when disarmed."""
    if _m.enabled():
        return Span(name, labels)
    return NULL_SPAN


class Timer:
    """Always measures wall time (``.elapsed_s``); records the sample into
    the registry histogram only when the layer is armed.  For call sites
    that need the elapsed time regardless (serve.py latency lines)."""

    __slots__ = ("name", "labels", "elapsed_s", "_t0")

    def __init__(self, name: str, labels: dict):
        self.name = name
        self.labels = labels
        self.elapsed_s = 0.0

    def __enter__(self):
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, exc_type, exc, tb):
        self.elapsed_s = time.perf_counter() - self._t0
        if _m.enabled():
            _m.REGISTRY.histogram(self.name, **self.labels).observe(
                self.elapsed_s * 1e3
            )
        return False


def timer(name: str, **labels) -> Timer:
    """Wall-clock timer; histogram names take a ``_ms`` suffix by convention."""
    return Timer(name, labels)


@contextlib.contextmanager
def profile(logdir: str = "/tmp/repro_profile"):
    """Wrap ``jax.profiler.trace`` when jax is importable and the layer is
    armed; degrades to a plain no-op context otherwise."""
    if not _m.enabled():
        yield
        return
    try:
        import jax

        ctx = jax.profiler.trace(logdir)
    except Exception:
        yield
        return
    with ctx:
        yield
