"""Exporters: Prometheus text exposition + JSON snapshot (+ diff).

Both render from the live registry with no extra deps.  The JSON
snapshot is the interchange format shared by ``serve.py
--metrics-dump``, the BENCH history entries (``benchmarks/run.py``)
and ``tools/obs_report.py``.
"""

from __future__ import annotations

import json
import math

from . import metrics as _m
from . import trace as _t

__all__ = ["diff", "render_prometheus", "snapshot", "write_snapshot"]


def _render_labels(labels: tuple) -> str:
    if not labels:
        return ""
    inner = ",".join(f'{k}="{v}"' for k, v in labels)
    return "{" + inner + "}"


def _key(name: str, labels: tuple) -> str:
    return name + _render_labels(labels)


def snapshot(registry: _m.Registry | None = None, events: bool = True) -> dict:
    """JSON-serialisable snapshot: counters, gauges, histogram summaries
    and (optionally) the recent trace-event ring."""
    reg = registry or _m.REGISTRY
    out: dict = {"counters": {}, "gauges": {}, "histograms": {}}
    for (kind, name, labels), m in reg.items():
        k = _key(name, labels)
        if kind == "Counter":
            out["counters"][k] = m.value
        elif kind == "Gauge":
            out["gauges"][k] = m.value
        else:
            out["histograms"][k] = m.summary()
    if events:
        out["events"] = _t.events()
    return out


def write_snapshot(
    path: str, registry: _m.Registry | None = None, events: bool = True
) -> dict:
    snap = snapshot(registry, events=events)
    with open(path, "w") as f:
        json.dump(snap, f, indent=2, sort_keys=True, default=str)
        f.write("\n")
    return snap


def render_prometheus(registry: _m.Registry | None = None) -> str:
    """Prometheus text exposition format (version 0.0.4)."""
    reg = registry or _m.REGISTRY
    lines: list = []
    seen_type: set = set()
    for (kind, name, labels), m in reg.items():
        if kind == "Counter":
            if name not in seen_type:
                lines.append(f"# TYPE {name} counter")
                seen_type.add(name)
            lines.append(f"{_key(name, labels)} {m.value}")
        elif kind == "Gauge":
            if name not in seen_type:
                lines.append(f"# TYPE {name} gauge")
                seen_type.add(name)
            lines.append(f"{_key(name, labels)} {m.value}")
        else:
            if name not in seen_type:
                lines.append(f"# TYPE {name} histogram")
                seen_type.add(name)
            for le, cum in m.buckets():
                le_s = "+Inf" if math.isinf(le) else f"{le:.6g}"
                blabels = labels + (("le", le_s),)
                lines.append(f"{name}_bucket{_render_labels(blabels)} {cum}")
            lines.append(f"{name}_sum{_render_labels(labels)} {m.sum:.6g}")
            lines.append(f"{name}_count{_render_labels(labels)} {m.count}")
    return "\n".join(lines) + "\n"


def diff(new: dict, old: dict) -> dict:
    """Delta between two JSON snapshots (new - old).

    Counters and gauges subtract numerically; histograms report
    count/sum deltas with the *new* percentiles (percentiles do not
    subtract meaningfully).  Keys only present in ``new`` pass through.
    """
    out: dict = {"counters": {}, "gauges": {}, "histograms": {}}
    for sect in ("counters", "gauges"):
        olds = old.get(sect, {})
        for k, v in new.get(sect, {}).items():
            out[sect][k] = v - olds.get(k, 0)
    oldh = old.get("histograms", {})
    for k, h in new.get("histograms", {}).items():
        prev = oldh.get(k, {})
        d = dict(h)
        d["count"] = h.get("count", 0) - prev.get("count", 0)
        d["sum"] = h.get("sum", 0.0) - prev.get("sum", 0.0)
        out["histograms"][k] = d
    return out
