"""RecSys models: DCN-v2, DLRM, DIN, BST (pure JAX).

The embedding lookup is the hot path.  JAX has no native EmbeddingBag: we
implement it with ``jnp.take`` (+ ``segment_sum`` for multi-hot bags in the
data pipeline); a Pallas kernel version lives in
``repro.kernels.embedding_bag``.  All sparse tables are stored as ONE flat
``[n_sparse * rows_per_field, embed_dim]`` array (row-sharded over the
``model`` mesh axis), with per-field offsets baked into the lookup indices --
the standard DLRM trick that makes the gather a single op.

Four entry points: ``forward`` (CTR logit), ``loss_fn`` (binary logloss),
``serve_score`` (forward without loss) and ``retrieval_step`` (one user vs.
``n_candidates`` items, vectorized -- NOT a loop).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .common import dense_init, rms_norm, split_keys


@dataclasses.dataclass(frozen=True)
class RecsysConfig:
    name: str = "recsys"
    kind: str = "dcn"  # dcn | dlrm | din | bst
    n_dense: int = 13
    n_sparse: int = 26
    embed_dim: int = 16
    rows_per_field: int = 1_000_000
    # dcn
    n_cross_layers: int = 3
    mlp: tuple = (1024, 1024, 512)
    # dlrm
    bot_mlp: tuple = (512, 256, 64)
    top_mlp: tuple = (512, 512, 256)
    # din / bst (sequential)
    seq_len: int = 0
    attn_mlp: tuple = (80, 40)
    n_blocks: int = 1
    n_heads: int = 8
    item_vocab: int = 2_000_000
    compute_dtype: Any = jnp.float32

    @property
    def table_rows(self) -> int:
        return self.n_sparse * self.rows_per_field

    def param_count(self) -> int:
        import numpy as np

        tree = jax.eval_shape(lambda k: init_params(k, self), jax.random.PRNGKey(0))
        return sum(int(np.prod(l.shape)) for l in jax.tree_util.tree_leaves(tree))


def _mlp_init(key, dims, name):
    ks = jax.random.split(key, len(dims) - 1)
    return [
        {"w": dense_init(ks[i], (dims[i], dims[i + 1])), "b": jnp.zeros((dims[i + 1],))}
        for i in range(len(dims) - 1)
    ]


def _mlp_apply(layers, x, act=jax.nn.relu, last_act=True):
    for i, l in enumerate(layers):
        x = x @ l["w"] + l["b"]
        if last_act or i + 1 < len(layers):
            x = act(x)
    return x


def init_params(key, cfg: RecsysConfig):
    ks = split_keys(key, ["table", "item", "cross", "mlp", "bot", "top", "attn", "blk", "out", "pos"])
    p: dict[str, Any] = {}
    d = cfg.embed_dim
    if cfg.kind in ("dcn", "dlrm"):
        p["table"] = dense_init(ks["table"], (cfg.table_rows, d), scale=0.01)
    else:
        p["item_table"] = dense_init(ks["item"], (cfg.item_vocab, d), scale=0.01)

    if cfg.kind == "dcn":
        x0_dim = cfg.n_dense + cfg.n_sparse * d
        kc = jax.random.split(ks["cross"], cfg.n_cross_layers)
        p["cross"] = [
            {"w": dense_init(kc[i], (x0_dim, x0_dim)), "b": jnp.zeros((x0_dim,))}
            for i in range(cfg.n_cross_layers)
        ]
        p["mlp"] = _mlp_init(ks["mlp"], (x0_dim, *cfg.mlp), "mlp")
        p["out"] = dense_init(ks["out"], (cfg.mlp[-1], 1))
    elif cfg.kind == "dlrm":
        p["bot"] = _mlp_init(ks["bot"], (cfg.n_dense, *cfg.bot_mlp), "bot")
        nvec = cfg.n_sparse + 1
        inter_dim = nvec * (nvec - 1) // 2 + cfg.bot_mlp[-1]
        p["top"] = _mlp_init(ks["top"], (inter_dim, *cfg.top_mlp), "top")
        p["out"] = dense_init(ks["out"], (cfg.top_mlp[-1], 1))
    elif cfg.kind == "din":
        p["attn"] = _mlp_init(ks["attn"], (4 * d, *cfg.attn_mlp, 1), "attn")
        p["mlp"] = _mlp_init(ks["mlp"], (3 * d, 200, 80), "mlp")
        p["out"] = dense_init(ks["out"], (80, 1))
    elif cfg.kind == "bst":
        L = cfg.seq_len + 1
        p["pos"] = dense_init(ks["pos"], (L, d), scale=0.02)
        kb = jax.random.split(ks["blk"], cfg.n_blocks)
        p["blocks"] = []
        for i in range(cfg.n_blocks):
            k1, k2, k3, k4 = jax.random.split(kb[i], 4)
            p["blocks"].append(
                {
                    "wqkv": dense_init(k1, (d, 3 * d)),
                    "wo": dense_init(k2, (d, d)),
                    "ln1": jnp.ones((d,)),
                    "ln2": jnp.ones((d,)),
                    "ff1": dense_init(k3, (d, 4 * d)),
                    "ff2": dense_init(k4, (4 * d, d)),
                }
            )
        p["mlp"] = _mlp_init(ks["mlp"], (L * d, 1024, 512, 256), "mlp")
        p["out"] = dense_init(ks["out"], (256, 1))
    else:
        raise ValueError(cfg.kind)
    return p


def param_specs(cfg: RecsysConfig, model_axis: str = "model"):
    tree = jax.eval_shape(lambda k: init_params(k, cfg), jax.random.PRNGKey(0))
    specs = jax.tree_util.tree_map(lambda _: P(), tree)
    if cfg.kind in ("dcn", "dlrm"):
        specs["table"] = P(model_axis, None)
    else:
        specs["item_table"] = P(model_axis, None)
    return specs


# --------------------------------------------------------------------------
# Embedding lookup (take-based; see repro.kernels.embedding_bag for Pallas)
# --------------------------------------------------------------------------

def embed_fields(table, sparse_ids, rows_per_field):
    """sparse_ids: [B, F] per-field ids -> [B, F, d] (ids offset per field)."""
    F = sparse_ids.shape[1]
    offs = (jnp.arange(F) * rows_per_field)[None, :]
    return jnp.take(table, sparse_ids + offs, axis=0)


# --------------------------------------------------------------------------
# Forward per model kind
# --------------------------------------------------------------------------

def ctr_head(params, dense, emb, cfg: RecsysConfig):
    """dcn/dlrm logits from a precomputed embedding block [B, F, d].

    Split out of ``forward`` so the sparse-update train step (cells.py)
    can differentiate w.r.t. ``emb`` instead of the full table."""
    if cfg.kind == "dcn":
        x0 = jnp.concatenate([dense, emb.reshape(emb.shape[0], -1)], -1)
        x = x0
        for l in params["cross"]:
            x = x0 * (x @ l["w"] + l["b"]) + x  # DCN-v2 cross
        h = _mlp_apply(params["mlp"], x)
        return (h @ params["out"])[:, 0]
    dv = _mlp_apply(params["bot"], dense)  # [B, 64]
    vecs = jnp.concatenate([dv[:, None, :], emb], axis=1)  # [B, 27, d]
    gram = jnp.einsum("bnd,bmd->bnm", vecs, vecs)
    n = vecs.shape[1]
    iu = jnp.triu_indices(n, k=1)
    inter = gram[:, iu[0], iu[1]]  # [B, n(n-1)/2]
    h = _mlp_apply(params["top"], jnp.concatenate([dv, inter], -1))
    return (h @ params["out"])[:, 0]


def forward(params, batch, cfg: RecsysConfig):
    if cfg.kind in ("dcn", "dlrm"):
        emb = embed_fields(params["table"], batch["sparse"], cfg.rows_per_field)
        return ctr_head(params, batch["dense"], emb, cfg)
    if cfg.kind == "din":
        hist = jnp.take(params["item_table"], batch["history"], axis=0)  # [B,L,d]
        tgt = jnp.take(params["item_table"], batch["target"], axis=0)  # [B,d]
        return _din_head(params, hist, batch["hist_mask"], tgt, cfg)
    if cfg.kind == "bst":
        hist = jnp.take(params["item_table"], batch["history"], axis=0)
        tgt = jnp.take(params["item_table"], batch["target"], axis=0)
        return _bst_head(params, hist, batch["hist_mask"], tgt, cfg)
    raise ValueError(cfg.kind)


def _din_head(params, hist, hist_mask, tgt, cfg):
    """hist: [B,L,d], tgt: [B,d] -> logits [B]."""
    B, L, d = hist.shape
    t = jnp.broadcast_to(tgt[:, None, :], hist.shape)
    a_in = jnp.concatenate([hist, t, hist - t, hist * t], axis=-1)  # [B,L,4d]
    scores = _mlp_apply(params["attn"], a_in, act=jax.nn.sigmoid, last_act=False)[..., 0]
    scores = jnp.where(hist_mask, scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1)
    user = jnp.einsum("bl,bld->bd", w, hist)
    h = _mlp_apply(params["mlp"], jnp.concatenate([user, tgt, user * tgt], -1))
    return (h @ params["out"])[:, 0]


def _bst_head(params, hist, hist_mask, tgt, cfg):
    B, L, d = hist.shape
    x = jnp.concatenate([hist, tgt[:, None, :]], axis=1) + params["pos"][None]
    mask = jnp.concatenate([hist_mask, jnp.ones((B, 1), bool)], axis=1)  # [B,L+1]
    H = cfg.n_heads
    dh = d // H
    for blk in params["blocks"]:
        h = rms_norm(x, blk["ln1"])
        qkv = h @ blk["wqkv"]
        q, k, v = jnp.split(qkv, 3, axis=-1)
        q = q.reshape(B, L + 1, H, dh)
        k = k.reshape(B, L + 1, H, dh)
        v = v.reshape(B, L + 1, H, dh)
        s = jnp.einsum("bshd,bthd->bhst", q, k) / math.sqrt(dh)
        s = jnp.where(mask[:, None, None, :], s, -1e30)
        p_attn = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bhst,bthd->bshd", p_attn, v).reshape(B, L + 1, d)
        x = x + o @ blk["wo"]
        h = rms_norm(x, blk["ln2"])
        x = x + jax.nn.relu(h @ blk["ff1"]) @ blk["ff2"]
    h = _mlp_apply(params["mlp"], x.reshape(B, -1))
    return (h @ params["out"])[:, 0]


def loss_fn(params, batch, cfg: RecsysConfig):
    logits = forward(params, batch, cfg).astype(jnp.float32)
    y = batch["label"].astype(jnp.float32)
    return jnp.mean(
        jnp.maximum(logits, 0) - logits * y + jnp.log1p(jnp.exp(-jnp.abs(logits)))
    )


def serve_score(params, batch, cfg: RecsysConfig):
    return forward(params, batch, cfg)


def retrieval_step(params, batch, cfg: RecsysConfig):
    """One user, n_candidates items: broadcast user features, vary the item.

    For dcn/dlrm the candidate replaces sparse field 0; for din/bst it is the
    attention target.  Vectorized over candidates (a batched-dot / batched
    model apply -- not a loop).
    """
    cand = batch["candidates"]  # [C]
    C = cand.shape[0]
    if cfg.kind in ("dcn", "dlrm"):
        sparse = jnp.broadcast_to(batch["sparse"], (C, cfg.n_sparse))
        sparse = sparse.at[:, 0].set(cand)
        dense = jnp.broadcast_to(batch["dense"], (C, cfg.n_dense))
        return forward(params, {"dense": dense, "sparse": sparse}, cfg)
    hist = jnp.take(params["item_table"], batch["history"], axis=0)  # [1,L,d]
    hist = jnp.broadcast_to(hist, (C, *hist.shape[1:]))
    mask = jnp.broadcast_to(batch["hist_mask"], (C, batch["hist_mask"].shape[1]))
    tgt = jnp.take(params["item_table"], cand, axis=0)  # [C,d]
    head = _din_head if cfg.kind == "din" else _bst_head
    return head(params, hist, mask, tgt, cfg)


# --------------------------------------------------------------------------
# Dry-run input specs
# --------------------------------------------------------------------------

def input_specs(cfg: RecsysConfig, kind: str, batch: int, n_candidates: int = 0):
    f32, i32 = jnp.float32, jnp.int32
    if kind == "retrieval":
        spec = {"candidates": jax.ShapeDtypeStruct((n_candidates,), i32)}
        if cfg.kind in ("dcn", "dlrm"):
            spec["dense"] = jax.ShapeDtypeStruct((1, cfg.n_dense), f32)
            spec["sparse"] = jax.ShapeDtypeStruct((1, cfg.n_sparse), i32)
        else:
            spec["history"] = jax.ShapeDtypeStruct((1, cfg.seq_len), i32)
            spec["hist_mask"] = jax.ShapeDtypeStruct((1, cfg.seq_len), jnp.bool_)
        return spec
    if cfg.kind in ("dcn", "dlrm"):
        spec = {
            "dense": jax.ShapeDtypeStruct((batch, cfg.n_dense), f32),
            "sparse": jax.ShapeDtypeStruct((batch, cfg.n_sparse), i32),
        }
    else:
        spec = {
            "history": jax.ShapeDtypeStruct((batch, cfg.seq_len), i32),
            "hist_mask": jax.ShapeDtypeStruct((batch, cfg.seq_len), jnp.bool_),
            "target": jax.ShapeDtypeStruct((batch,), i32),
        }
    if kind == "train":
        spec["label"] = jax.ShapeDtypeStruct((batch,), f32)
    return spec


def batch_specs(cfg: RecsysConfig, kind: str, data_axes=("pod", "data")):
    d = data_axes
    if kind == "retrieval":
        spec = {"candidates": P(d)}
        if cfg.kind in ("dcn", "dlrm"):
            spec.update({"dense": P(), "sparse": P()})
        else:
            spec.update({"history": P(), "hist_mask": P()})
        return spec
    if cfg.kind in ("dcn", "dlrm"):
        spec = {"dense": P(d), "sparse": P(d)}
    else:
        spec = {"history": P(d), "hist_mask": P(d), "target": P(d)}
    if kind == "train":
        spec["label"] = P(d)
    return spec
