"""Decoder-only transformer LM: dense and MoE, GQA, RoPE, SwiGLU, qk-norm,
QKV-bias, sliding-window attention, chunked (flash-style) attention,
scan-over-layers with remat.  Pure functional JAX; params are pytrees.

Supports the 5 assigned LM architectures (command-r-35b, qwen1.5-0.5b,
qwen3-0.6b, moonshot-v1-16b-a3b, mixtral-8x22b) through `TransformerConfig`.

Three entry points (all jit/pjit friendly):
  * ``train_step(params, opt_state, batch, cfg)``  -- loss + AdamW update
  * ``prefill_step(params, tokens, cfg)``          -- logits for a prompt +
                                                      freshly-built KV cache
  * ``serve_step(params, cache, token, cfg)``      -- one decode step
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import get_abstract_mesh

from .common import dense_init, rms_norm, split_keys


def maybe_shard(x: jnp.ndarray, spec: P) -> jnp.ndarray:
    """with_sharding_constraint that degrades gracefully outside a mesh.

    Axis names absent from the ambient mesh are dropped from the spec, so the
    same model code runs under the single-pod mesh (no "pod" axis), the
    multi-pod mesh, and un-meshed CPU smoke tests.
    """
    mesh = get_abstract_mesh()
    if mesh is None or not mesh.axis_names:
        return x
    names = set(mesh.axis_names)

    def keep(entry):
        if entry is None:
            return None
        if isinstance(entry, (tuple, list)):
            kept = tuple(e for e in entry if e in names)
            return kept if kept else None
        return entry if entry in names else None

    new_spec = P(*(keep(e) for e in spec))
    return jax.lax.with_sharding_constraint(x, new_spec)


@dataclasses.dataclass(frozen=True)
class TransformerConfig:
    name: str = "lm"
    n_layers: int = 2
    d_model: int = 128
    n_heads: int = 4
    n_kv_heads: int = 4
    d_head: int = 32
    d_ff: int = 512
    vocab: int = 1024
    qkv_bias: bool = False
    qk_norm: bool = False
    # MoE (n_experts == 0 -> dense FFN)
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    moe_groups: int = 1  # GShard-style groups; set to the data-shard count so
    # dispatch positions (and the capacity buffer) are local per shard
    moe_shard_map: bool = False  # explicit-collective MoE (see moe_ffn_shard_map)
    # attention
    sliding_window: int = 0  # 0 => full causal attention
    rope_theta: float = 10_000.0
    attn_chunk: int = 1024  # flash-style chunking threshold / block
    loss_chunk: int = 512  # sequence chunking for the CE loss
    # numerics
    param_dtype: Any = jnp.float32
    compute_dtype: Any = jnp.bfloat16
    remat: bool = True

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.d_head

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.d_head

    def param_count(self) -> int:
        c = self
        attn = c.d_model * (c.q_dim + 2 * c.kv_dim) + c.q_dim * c.d_model
        if c.qkv_bias:
            attn += c.q_dim + 2 * c.kv_dim
        if c.qk_norm:
            attn += 2 * c.d_head
        if c.is_moe:
            ffn = c.n_experts * 3 * c.d_model * c.d_ff + c.d_model * c.n_experts
        else:
            ffn = 3 * c.d_model * c.d_ff
        per_layer = attn + ffn + 2 * c.d_model
        return c.n_layers * per_layer + 2 * c.vocab * c.d_model + c.d_model

    def active_param_count(self) -> int:
        """Activated params per token (MoE counts top_k experts only)."""
        if not self.is_moe:
            return self.param_count()
        c = self
        attn = c.d_model * (c.q_dim + 2 * c.kv_dim) + c.q_dim * c.d_model
        ffn = c.top_k * 3 * c.d_model * c.d_ff + c.d_model * c.n_experts
        per_layer = attn + ffn + 2 * c.d_model
        return c.n_layers * per_layer + 2 * c.vocab * c.d_model + c.d_model


# ==========================================================================
# Parameter init (stacked [L, ...] leaves for scan-over-layers)
# ==========================================================================

def init_params(key, cfg: TransformerConfig):
    L, d, q, kv, ff, V = (
        cfg.n_layers,
        cfg.d_model,
        cfg.q_dim,
        cfg.kv_dim,
        cfg.d_ff,
        cfg.vocab,
    )
    ks = split_keys(key, ["embed", "head", "wq", "wk", "wv", "wo", "ffn1", "ffn2", "ffn3", "router"])
    pd = cfg.param_dtype
    layers: dict[str, Any] = {
        "wq": dense_init(ks["wq"], (L, d, q), dtype=pd),
        "wk": dense_init(ks["wk"], (L, d, kv), dtype=pd),
        "wv": dense_init(ks["wv"], (L, d, kv), dtype=pd),
        "wo": dense_init(ks["wo"], (L, q, d), dtype=pd),
        "ln1": jnp.ones((L, d), pd),
        "ln2": jnp.ones((L, d), pd),
    }
    if cfg.qkv_bias:
        layers["bq"] = jnp.zeros((L, q), pd)
        layers["bk"] = jnp.zeros((L, kv), pd)
        layers["bv"] = jnp.zeros((L, kv), pd)
    if cfg.qk_norm:
        layers["q_norm"] = jnp.ones((L, cfg.d_head), pd)
        layers["k_norm"] = jnp.ones((L, cfg.d_head), pd)
    if cfg.is_moe:
        E = cfg.n_experts
        layers["router"] = dense_init(ks["router"], (L, d, E), dtype=pd)
        layers["w1"] = dense_init(ks["ffn1"], (L, E, d, ff), dtype=pd)
        layers["w3"] = dense_init(ks["ffn3"], (L, E, d, ff), dtype=pd)
        layers["w2"] = dense_init(ks["ffn2"], (L, E, ff, d), dtype=pd)
    else:
        layers["w1"] = dense_init(ks["ffn1"], (L, d, ff), dtype=pd)
        layers["w3"] = dense_init(ks["ffn3"], (L, d, ff), dtype=pd)
        layers["w2"] = dense_init(ks["ffn2"], (L, ff, d), dtype=pd)
    return {
        "embed": dense_init(ks["embed"], (V, d), scale=0.02, dtype=pd),
        "layers": layers,
        "final_ln": jnp.ones((d,), pd),
        "lm_head": dense_init(ks["head"], (d, V), dtype=pd),
    }


def param_specs(cfg: TransformerConfig, model_axis: str = "model", tp: int = 16):
    """PartitionSpec tree matching init_params (Megatron TP over `model`)."""
    m = model_axis
    kv_shardable = cfg.n_kv_heads % tp == 0
    layers: dict[str, Any] = {
        "wq": P(None, None, m),
        "wk": P(None, None, m) if kv_shardable else P(None, None, None),
        "wv": P(None, None, m) if kv_shardable else P(None, None, None),
        "wo": P(None, m, None),
        "ln1": P(None, None),
        "ln2": P(None, None),
    }
    if cfg.qkv_bias:
        layers["bq"] = P(None, m)
        layers["bk"] = P(None, m) if kv_shardable else P(None, None)
        layers["bv"] = P(None, m) if kv_shardable else P(None, None)
    if cfg.qk_norm:
        layers["q_norm"] = P(None, None)
        layers["k_norm"] = P(None, None)
    if cfg.is_moe:
        if cfg.n_experts % tp == 0:  # expert parallelism over `model`
            layers["router"] = P(None, None, None)
            layers["w1"] = P(None, m, None, None)
            layers["w3"] = P(None, m, None, None)
            layers["w2"] = P(None, m, None, None)
        else:  # TP inside each expert
            layers["router"] = P(None, None, None)
            layers["w1"] = P(None, None, None, m)
            layers["w3"] = P(None, None, None, m)
            layers["w2"] = P(None, None, m, None)
    else:
        layers["w1"] = P(None, None, m)
        layers["w3"] = P(None, None, m)
        layers["w2"] = P(None, m, None)
    return {
        "embed": P(m, None),
        "layers": layers,
        "final_ln": P(None),
        "lm_head": P(None, m),
    }


# ==========================================================================
# RoPE
# ==========================================================================

def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: [..., S, H, D]; positions: [..., S]."""
    d = x.shape[-1]
    half = d // 2
    freqs = jnp.exp(-math.log(theta) * jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs  # [..., S, half]
    cos = jnp.cos(ang)[..., None, :]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(x.dtype)


# ==========================================================================
# Attention
# ==========================================================================

def _attn_scores_mask(q_pos, k_pos, window: int):
    """[Sq, Sk] bool mask: causal, optionally sliding-window."""
    m = k_pos[None, :] <= q_pos[:, None]
    if window > 0:
        m &= k_pos[None, :] > (q_pos[:, None] - window)
    return m


def full_attention(q, k, v, q_pos, k_pos, window: int):
    """q: [B,Sq,H,D]; k,v: [B,Sk,KV,D].  Materializes [Sq,Sk] scores."""
    B, Sq, H, D = q.shape
    KV = k.shape[2]
    G = H // KV
    qg = q.reshape(B, Sq, KV, G, D)
    s = jnp.einsum("bskgd,btkd->bkgst", qg.astype(jnp.float32), k.astype(jnp.float32))
    s *= 1.0 / math.sqrt(D)
    mask = _attn_scores_mask(q_pos, k_pos, window)
    s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgst,btkd->bskgd", p, v.astype(jnp.float32))
    return o.reshape(B, Sq, H, D).astype(q.dtype)


def chunked_attention(q, k, v, q_pos, k_pos, window: int, chunk: int):
    """Flash-style online-softmax attention, O(chunk^2) live scores.

    Outer scan over q chunks, inner scan over kv chunks with running
    (max, denom, acc) carried in f32.
    """
    B, S, H, D = q.shape
    KV = k.shape[2]
    G = H // KV
    C = chunk
    nq = S // C
    nk = k.shape[1] // C
    qg = q.reshape(B, nq, C, KV, G, D)
    kc = k.reshape(B, nk, C, KV, D)
    vc = v.reshape(B, nk, C, KV, D)
    qpc = q_pos.reshape(nq, C)
    kpc = k_pos.reshape(nk, C)
    scale = 1.0 / math.sqrt(D)

    def q_block(qi):
        qb = qg[:, qi].astype(jnp.float32) * scale  # [B,C,KV,G,D]
        qp = qpc[qi]

        def kv_step(carry, inputs):
            m, l, acc = carry
            kb, vb, kp = inputs
            s = jnp.einsum("bckgd,btkd->bkgct", qb, kb.astype(jnp.float32))
            mask = _attn_scores_mask(qp, kp, window)[None, None, None]
            s = jnp.where(mask, s, -1e30)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None]) * mask
            corr = jnp.exp(m - m_new)
            l = l * corr + p.sum(axis=-1)
            acc = acc * corr[..., None] + jnp.einsum(
                "bkgct,btkd->bkgcd", p, vb.astype(jnp.float32)
            )
            return (m_new, l, acc), None

        m0 = jnp.full((B, KV, G, C), -1e30, jnp.float32)
        l0 = jnp.zeros((B, KV, G, C), jnp.float32)
        a0 = jnp.zeros((B, KV, G, C, D), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step,
            (m0, l0, a0),
            (kc.swapaxes(0, 1), vc.swapaxes(0, 1), kpc),
        )
        o = acc / jnp.maximum(l, 1e-30)[..., None]  # [B,KV,G,C,D]
        return o.transpose(0, 3, 1, 2, 4).reshape(B, C, H, D)

    out = jax.lax.map(q_block, jnp.arange(nq))  # [nq,B,C,H,D]
    return out.transpose(1, 0, 2, 3, 4).reshape(B, S, H, D).astype(q.dtype)


# ==========================================================================
# FFN (dense SwiGLU / MoE with sort-based dispatch)
# ==========================================================================

def dense_ffn(x, w1, w3, w2):
    h = jax.nn.silu(x @ w1) * (x @ w3)
    return h @ w2


def moe_ffn(x, router, w1, w3, w2, cfg: TransformerConfig):
    """Sort-free top-k dispatch: cumsum position assignment.

    x: [T, d].  Returns ([T, d], aux_loss).

    Perf note (EXPERIMENTS.md section Perf, mixtral hillclimb): the first
    implementation dispatched via a global ``argsort`` over T*k (token,
    expert) pairs and scatter-combined -- under pjit both the sharded sort
    and the replicated [E, cap, d] buffer exploded into hundreds of GB of
    all-gather traffic.  This version:
      * derives position-in-expert with an exclusive ``cumsum`` over the
        [T, E] assignment mask (sharding-friendly prefix sum, no sort);
      * combines by *gathering* y[e, pos] back per (token, slot) -- no
        scatter on the combine path;
      * constrains the dispatch buffer so the capacity dim follows the
        batch axes and (for EP) experts follow `model`.
    """
    T, d = x.shape
    E, k = cfg.n_experts, cfg.top_k
    G = max(1, min(cfg.moe_groups, T))
    while T % G:
        G //= 2
    Tg = T // G
    logits = (x.astype(jnp.float32) @ router.astype(jnp.float32))  # [T, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, k)  # [T, k]
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    cap = int(math.ceil(Tg * k / E * cfg.capacity_factor))
    cap = max(cap, 4)
    idx_g = idx.reshape(G, Tg, k)
    # assignment mask [G, Tg, E]; exclusive prefix WITHIN each group ->
    # every (expert, group) slice of the buffer is written only by its own
    # group's tokens, so dispatch + combine stay shard-local under pjit
    mask = jnp.zeros((G, Tg, E), jnp.int32)
    g_i = jax.lax.broadcasted_iota(jnp.int32, (G, Tg, k), 0)
    t_i = jax.lax.broadcasted_iota(jnp.int32, (G, Tg, k), 1)
    mask = mask.at[g_i, t_i, idx_g].add(1)
    pos_te = jnp.cumsum(mask, axis=1) - mask  # [G, Tg, E]
    pos = jnp.take_along_axis(pos_te, idx_g, axis=2)  # [G, Tg, k]
    keep = pos < cap
    pos_c = jnp.where(keep, pos, cap - 1)

    ep = E % 16 == 0
    dsh = ("pod", "data")
    buf = jnp.zeros((G, E, cap, d), x.dtype)
    xk = jnp.where(keep[..., None], x.reshape(G, Tg, 1, d), 0)  # [G,Tg,k,d]
    buf = buf.at[
        g_i.reshape(G, Tg * k),
        idx_g.reshape(G, Tg * k),
        pos_c.reshape(G, Tg * k),
    ].add(xk.reshape(G, Tg * k, d))
    buf = maybe_shard(buf, P(dsh, "model" if ep else None, None, None))
    h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", buf, w1)) * jnp.einsum(
        "gecd,edf->gecf", buf, w3
    )
    y = jnp.einsum("gecf,efd->gecd", h, w2)  # [G, E, cap, d]
    # NO sharding constraint on y: with TP-in-expert the w2 contraction
    # leaves partial sums over `model`; the gate-weighted combine below is
    # linear, so XLA can defer the all-reduce until AFTER the combine --
    # reducing [T, d] token activations instead of the 2.5x-expanded
    # [G, E, cap, d] buffer (EXPERIMENTS.md Perf, mixtral iteration 3)
    # combine by GATHER within the group: out[g,t] = sum_j gate_j * y[g,e_j,pos_j]
    yk = y[
        g_i.reshape(G, Tg * k),
        idx_g.reshape(G, Tg * k),
        pos_c.reshape(G, Tg * k),
    ].reshape(G, Tg, k, d)
    out = jnp.einsum(
        "gtk,gtkd->gtd", (gates.reshape(G, Tg, k) * keep).astype(yk.dtype), yk
    ).reshape(T, d)
    # aux load-balancing loss (Switch-style)
    me = jnp.mean(probs, axis=0)
    ce = jnp.sum(mask, axis=(0, 1)).astype(jnp.float32) / (T * k)
    aux = E * jnp.sum(me * ce)
    return out.astype(x.dtype), aux


def _moe_local(x, router, w1, w3, w2, cfg: TransformerConfig, n_local_experts: int,
               model_axis: str | None, data_axes_names: tuple = ()):
    """Per-shard MoE body used inside shard_map.

    x: [T_local, d] (this data shard's tokens).  Dispatch positions are
    computed locally (one GShard group per shard).  Two modes:
      * TP-in-expert (w1 local shape [E, d, ff/tp]): compute partial y,
        combine locally, ``psum`` the TOKEN-sized output over `model` --
        this is the whole point: the wire carries [T_local, d], not the
        2.5x-expanded capacity buffer (and never in f32).
      * EP (w1 local [E/tp, d, ff]): ``all_to_all`` the capacity buffer over
        `model` so each shard computes its resident experts, then a2a back.
    """
    T, d = x.shape
    E, k = cfg.n_experts, cfg.top_k
    logits = (x.astype(jnp.float32) @ router.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, k)
    gates = (gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)).astype(x.dtype)

    cap = max(4, int(math.ceil(T * k / E * cfg.capacity_factor)))
    mask = jnp.zeros((T, E), jnp.int32).at[jnp.arange(T)[:, None], idx].add(1)
    pos = jnp.take_along_axis(jnp.cumsum(mask, axis=0) - mask, idx, axis=1)
    keep = pos < cap
    pos_c = jnp.where(keep, pos, cap - 1)

    buf = jnp.zeros((E, cap, d), x.dtype)
    xk = jnp.where(keep[..., None], x[:, None, :], 0)
    buf = buf.at[idx.reshape(-1), pos_c.reshape(-1)].add(xk.reshape(T * k, d))

    ep = n_local_experts < E
    if ep and model_axis is not None:
        tp = E // n_local_experts
        # [E, cap, d] -> [tp, E/tp, cap, d]; a2a over model: shard m receives
        # every shard's rows for ITS resident experts (dim 0 becomes the
        # source-shard index) -> transpose to [E/tp, tp*cap, d]
        bufe = jax.lax.all_to_all(
            buf.reshape(tp, n_local_experts, cap, d), model_axis, 0, 0
        )
        bufe = bufe.transpose(1, 0, 2, 3).reshape(n_local_experts, tp * cap, d)
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", bufe, w1)) * jnp.einsum(
            "ecd,edf->ecf", bufe, w3
        )
        y = jnp.einsum("ecf,efd->ecd", h, w2)
        y = y.reshape(n_local_experts, tp, cap, d).transpose(1, 0, 2, 3)
        y = jax.lax.all_to_all(y, model_axis, 0, 0).reshape(E, cap, d)
    else:
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, w1)) * jnp.einsum(
            "ecd,edf->ecf", buf, w3
        )
        y = jnp.einsum("ecf,efd->ecd", h, w2)  # partial over model when TP

    yk = y[idx.reshape(-1), pos_c.reshape(-1)].reshape(T, k, d)
    out = jnp.einsum("tk,tkd->td", (gates * keep.astype(gates.dtype)), yk)
    if not ep and model_axis is not None:
        # keep the wire in bf16: the reduction operand must not be upcast
        out = jax.lax.psum(out.astype(x.dtype), model_axis)
    me = jnp.mean(probs, axis=0)
    ce = jnp.sum(mask, axis=0).astype(jnp.float32) / (T * k)
    aux = E * jnp.sum(me * ce)
    for ax in data_axes_names:
        aux = jax.lax.pmean(aux, ax)
    if model_axis is not None:
        aux = jax.lax.pmean(aux, model_axis)
    return out.astype(x.dtype), aux


def moe_ffn_shard_map(x, router, w1, w3, w2, cfg: TransformerConfig):
    """Explicit-collective MoE via shard_map (EXPERIMENTS.md Perf).

    Falls back to the pjit ``moe_ffn`` when no mesh is active.
    """
    mesh = get_abstract_mesh()
    if mesh is None or "model" not in mesh.axis_names:
        return moe_ffn(x, router, w1, w3, w2, cfg)
    dsh = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    tp = mesh.shape["model"]
    ds = 1
    for a in dsh:
        ds *= mesh.shape[a]
    E = cfg.n_experts
    T = x.shape[0]
    ep = E % tp == 0 and T % (ds * tp) == 0 and T >= 4 * ds * tp
    if (not ep and (T % ds != 0 or T < 4 * ds)) or not dsh:
        # decode-sized token counts cannot shard over the mesh: the pjit
        # path's tiny buffers are fine there
        return moe_ffn(x, router, w1, w3, w2, cfg)
    w_spec = P("model", None, None) if ep else P(None, None, "model")
    w2_spec = P("model", None, None) if ep else P(None, "model", None)
    n_local = E // tp if ep else E
    # EP: tokens are sharded over `model` as well (sequence-parallel entry),
    # so every device dispatches only ITS token slice -- no redundant expert
    # rows in the a2a.  TP-in-expert: tokens replicated over `model` (each
    # shard owns an ff slice of every token) + token-sized psum at the end.
    x_spec = P(dsh + ("model",), None) if ep else P(dsh, None)

    def body(xl, rl, w1l, w3l, w2l):
        return _moe_local(xl, rl, w1l, w3l, w2l, cfg, n_local, "model", dsh)

    return jax.shard_map(
        body,
        mesh=mesh,
        in_specs=(x_spec, P(None, None), w_spec, w_spec, w2_spec),
        out_specs=(x_spec, P()),
        check_vma=False,
    )(x, router, w1, w3, w2)


# ==========================================================================
# Layer / forward
# ==========================================================================

def _layer(x, lp, positions, cfg: TransformerConfig, kv_cache=None, cache_pos=None):
    """One transformer block.  x: [B,S,d].  Returns (y, aux, new_kv)."""
    cd = cfg.compute_dtype
    B, S, d = x.shape
    h = rms_norm(x, lp["ln1"]).astype(cd)
    q = h @ lp["wq"].astype(cd)
    kk = h @ lp["wk"].astype(cd)
    vv = h @ lp["wv"].astype(cd)
    if cfg.qkv_bias:
        q = q + lp["bq"].astype(cd)
        kk = kk + lp["bk"].astype(cd)
        vv = vv + lp["bv"].astype(cd)
    q = q.reshape(B, S, cfg.n_heads, cfg.d_head)
    kk = kk.reshape(B, S, cfg.n_kv_heads, cfg.d_head)
    vv = vv.reshape(B, S, cfg.n_kv_heads, cfg.d_head)
    if cfg.qk_norm:
        q = rms_norm(q, lp["q_norm"])
        kk = rms_norm(kk, lp["k_norm"])
    q = rope(q, positions, cfg.rope_theta)
    kk = rope(kk, positions, cfg.rope_theta)

    new_kv = None
    if kv_cache is not None:
        ck, cv = kv_cache  # [B, S_cache, KV, D]
        if cache_pos is not None:  # decode: insert at cache_pos (ring for SWA)
            Sc = ck.shape[1]
            slot = cache_pos % Sc if cfg.sliding_window > 0 else cache_pos
            ck = jax.lax.dynamic_update_slice(ck, kk, (0, slot, 0, 0))
            cv = jax.lax.dynamic_update_slice(cv, vv, (0, slot, 0, 0))
            k_pos_abs = _cache_positions(Sc, cache_pos, cfg)
            o = full_attention(q, ck, cv, positions, k_pos_abs, cfg.sliding_window)
            new_kv = (ck, cv)
        else:
            raise ValueError("cache without cache_pos")
    else:
        if S > cfg.attn_chunk and S % cfg.attn_chunk == 0:
            o = chunked_attention(
                q, kk, vv, positions, positions, cfg.sliding_window, cfg.attn_chunk
            )
        else:
            o = full_attention(q, kk, vv, positions, positions, cfg.sliding_window)
        new_kv = (kk, vv)
    o = o.reshape(B, S, cfg.q_dim) @ lp["wo"].astype(cd)
    x = x + o.astype(x.dtype)

    h = rms_norm(x, lp["ln2"]).astype(cd)
    if cfg.is_moe:
        moe = moe_ffn_shard_map if cfg.moe_shard_map else moe_ffn
        y, aux = moe(
            h.reshape(B * S, d),
            lp["router"].astype(cd),
            lp["w1"].astype(cd),
            lp["w3"].astype(cd),
            lp["w2"].astype(cd),
            cfg,
        )
        y = y.reshape(B, S, d)
    else:
        y = dense_ffn(h, lp["w1"].astype(cd), lp["w3"].astype(cd), lp["w2"].astype(cd))
        aux = jnp.float32(0.0)
    return x + y.astype(x.dtype), aux, new_kv


def _cache_positions(Sc: int, cache_pos, cfg: TransformerConfig):
    """Absolute positions held by each cache slot at decode time."""
    slots = jnp.arange(Sc)
    if cfg.sliding_window > 0:
        # ring buffer: slot s holds the latest absolute position p <= cache_pos
        # with p % Sc == s; invalid (future) slots get a huge position.
        base = (cache_pos // Sc) * Sc
        pos = jnp.where(slots <= cache_pos % Sc, base + slots, base - Sc + slots)
        return jnp.where(pos >= 0, pos, jnp.iinfo(jnp.int32).max)
    return jnp.where(slots <= cache_pos, slots, jnp.iinfo(jnp.int32).max)


def forward(params, tokens, cfg: TransformerConfig, positions=None):
    """tokens: [B,S] -> final hidden states [B,S,d] (pre lm_head)."""
    B, S = tokens.shape
    if positions is None:
        positions = jnp.arange(S)
    x = params["embed"][tokens].astype(cfg.compute_dtype)
    x = maybe_shard(x, P(("pod", "data"), None, None))

    def body(x, lp):
        y, aux, _ = _layer(x, lp, positions, cfg)
        return y, aux

    if cfg.remat:
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        )
    x, auxs = jax.lax.scan(body, x, params["layers"])
    x = rms_norm(x, params["final_ln"])
    return x, auxs.sum()


def lm_loss(params, tokens, labels, cfg: TransformerConfig):
    """Chunked cross-entropy over the vocab (avoids [B,S,V] materialization)."""
    x, aux = forward(params, tokens, cfg)
    B, S, d = x.shape
    C = min(cfg.loss_chunk, S)
    nc = S // C
    head = params["lm_head"].astype(cfg.compute_dtype)

    def chunk_loss(ci):
        xs = jax.lax.dynamic_slice(x, (0, ci * C, 0), (B, C, d))
        ls = jax.lax.dynamic_slice(labels, (0, ci * C), (B, C))
        logits = (xs @ head).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, ls[..., None], axis=-1)[..., 0]
        return (lse - gold).sum()

    total = jax.lax.map(chunk_loss, jnp.arange(nc)).sum()
    rem = S - nc * C
    if rem:
        logits = (x[:, nc * C :] @ head).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, labels[:, nc * C :][..., None], -1)[..., 0]
        total = total + (lse - gold).sum()
    return total / (B * S) + 0.01 * aux


# ==========================================================================
# Serving
# ==========================================================================

def init_cache(cfg: TransformerConfig, batch: int, max_len: int):
    Sc = min(max_len, cfg.sliding_window) if cfg.sliding_window > 0 else max_len
    shape = (cfg.n_layers, 2, batch, Sc, cfg.n_kv_heads, cfg.d_head)
    return jnp.zeros(shape, cfg.compute_dtype)


def prefill_step(params, tokens, cfg: TransformerConfig):
    """Prompt forward: returns last-position logits + KV cache."""
    B, S = tokens.shape
    positions = jnp.arange(S)
    x = params["embed"][tokens].astype(cfg.compute_dtype)
    x = maybe_shard(x, P(("pod", "data"), None, None))

    def body(x, lp):
        y, _aux, kv = _layer(x, lp, positions, cfg)
        if cfg.sliding_window > 0 and kv[0].shape[1] > cfg.sliding_window:
            kv = tuple(z[:, -cfg.sliding_window :] for z in kv)
        return y, jnp.stack([kv[0], kv[1]])

    if cfg.remat:
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        )
    x, cache = jax.lax.scan(body, x, params["layers"])
    x = rms_norm(x[:, -1:], params["final_ln"])
    logits = (x @ params["lm_head"].astype(cfg.compute_dtype)).astype(jnp.float32)
    return logits[:, 0], cache


def serve_step(params, cache, token, cache_pos, cfg: TransformerConfig):
    """One decode step.  cache: [L,2,B,Sc,KV,D]; token: [B] int32."""
    positions = jnp.full((1,), cache_pos, jnp.int32)
    x = params["embed"][token[:, None]].astype(cfg.compute_dtype)

    def body(x, inputs):
        lp, kv = inputs
        y, _aux, new_kv = _layer(
            x, lp, positions, cfg, kv_cache=(kv[0], kv[1]), cache_pos=cache_pos
        )
        return y, jnp.stack([new_kv[0], new_kv[1]])

    x, new_cache = jax.lax.scan(body, x, (params["layers"], cache))
    x = rms_norm(x, params["final_ln"])
    logits = (x @ params["lm_head"].astype(cfg.compute_dtype)).astype(jnp.float32)
    return logits[:, 0], new_cache


# ==========================================================================
# Dry-run input specs
# ==========================================================================

def input_specs(cfg: TransformerConfig, shape_kind: str, seq_len: int, batch: int):
    """ShapeDtypeStructs + PartitionSpecs for each entry point."""
    tok = jax.ShapeDtypeStruct((batch, seq_len), jnp.int32)
    if shape_kind == "train":
        return {"tokens": tok, "labels": tok}
    if shape_kind == "prefill":
        return {"tokens": tok}
    if shape_kind == "decode":
        Sc = min(seq_len, cfg.sliding_window) if cfg.sliding_window > 0 else seq_len
        cache = jax.ShapeDtypeStruct(
            (cfg.n_layers, 2, batch, Sc, cfg.n_kv_heads, cfg.d_head),
            cfg.compute_dtype,
        )
        return {
            "cache": cache,
            "token": jax.ShapeDtypeStruct((batch,), jnp.int32),
        }
    raise ValueError(shape_kind)
