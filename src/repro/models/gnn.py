"""GIN (Graph Isomorphism Network, arXiv:1810.00826) in pure JAX.

Message passing is `jax.ops.segment_sum` over an edge-index -> node scatter
(JAX has no CSR SpMM; this IS the system, per the assignment notes).  The
`eps` parameters are learnable (GIN-eps).

Supported input regimes (all padded/masked to static shapes):
  * full-batch node classification (cora-like / ogbn-products-like),
  * sampled-subgraph mini-batch training (neighbor sampler in
    ``repro.data.graph_data``),
  * batched small graphs with segment-sum readout (molecule).

Normalization: the original model uses BatchNorm; we use LayerNorm to stay
functional/stateless (noted in DESIGN.md as an adaptation).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .common import dense_init, layer_norm


@dataclasses.dataclass(frozen=True)
class GINConfig:
    name: str = "gin"
    n_layers: int = 5
    d_in: int = 1433
    d_hidden: int = 64
    n_classes: int = 7
    graph_readout: bool = False  # True => graph classification (molecule)
    message_dtype: str = "float32"  # "bfloat16" halves the all_gather wire
    # bytes in the dst-sharded path (accumulation stays f32)


def init_params(key, cfg: GINConfig):
    ks = jax.random.split(key, cfg.n_layers + 1)
    layers = []
    d_prev = cfg.d_in
    for l in range(cfg.n_layers):
        k1, k2 = jax.random.split(ks[l])
        layers.append(
            {
                "eps": jnp.zeros((), jnp.float32),
                "w1": dense_init(k1, (d_prev, cfg.d_hidden)),
                "b1": jnp.zeros((cfg.d_hidden,)),
                "w2": dense_init(k2, (cfg.d_hidden, cfg.d_hidden)),
                "b2": jnp.zeros((cfg.d_hidden,)),
                "ln_scale": jnp.ones((cfg.d_hidden,)),
                "ln_bias": jnp.zeros((cfg.d_hidden,)),
            }
        )
        d_prev = cfg.d_hidden
    head = dense_init(ks[-1], (cfg.d_hidden, cfg.n_classes))
    return {"layers": layers, "head": head, "head_b": jnp.zeros((cfg.n_classes,))}


def param_specs(cfg: GINConfig, model_axis: str = "model"):
    """GIN is tiny -> replicate everything."""
    return jax.tree_util.tree_map(lambda _: P(), init_params_shape_tree(cfg))


def init_params_shape_tree(cfg: GINConfig):
    return jax.eval_shape(lambda k: init_params(k, cfg), jax.random.PRNGKey(0))


def forward(params, feats, edges, edge_mask, cfg: GINConfig, graph_ids=None, n_graphs=0):
    """feats: [N, d_in]; edges: [2, E] (src, dst); edge_mask: [E] bool.

    Padded edges point at node 0 but are masked out of the aggregation.
    """
    n = feats.shape[0]
    h = feats
    src, dst = edges[0], edges[1]
    for lp in params["layers"]:
        msg = h[src] * edge_mask[:, None].astype(h.dtype)
        agg = jax.ops.segment_sum(msg, dst, num_segments=n)
        z = (1.0 + lp["eps"]) * h + agg
        z = jax.nn.relu(z @ lp["w1"] + lp["b1"])
        z = z @ lp["w2"] + lp["b2"]
        h = layer_norm(z, lp["ln_scale"], lp["ln_bias"])
    if cfg.graph_readout:
        assert graph_ids is not None
        g = jax.ops.segment_sum(h, graph_ids, num_segments=n_graphs)
        return g @ params["head"] + params["head_b"]
    return h @ params["head"] + params["head_b"]


def loss_fn(params, batch, cfg: GINConfig):
    """batch: feats, edges, edge_mask, labels, label_mask (+ graph_ids)."""
    if cfg.graph_readout:
        logits = forward(
            params,
            batch["feats"],
            batch["edges"],
            batch["edge_mask"],
            cfg,
            graph_ids=batch["graph_ids"],
            n_graphs=batch["labels"].shape[0],
        )
        labels = batch["labels"]
        mask = jnp.ones(labels.shape[0], jnp.float32)
    else:
        logits = forward(params, batch["feats"], batch["edges"], batch["edge_mask"], cfg)
        labels = batch["labels"]
        mask = batch["label_mask"].astype(jnp.float32)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, labels[:, None], axis=-1)[:, 0]
    return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)


# ==========================================================================
# dst-aligned sharded message passing (EXPERIMENTS.md Perf, gin-tu hillclimb)
# ==========================================================================
#
# Baseline full-batch GIN replicated the node features and all-reduced the
# [N, d] partial aggregations per layer (collective-bound, 256x redundant
# MLP compute).  This path shards nodes AND edges over every mesh axis:
#
#   * the pipeline delivers edges grouped by destination shard (CSR is
#     dst-sorted, so this is a layout contract, not extra work): shard s
#     holds only edges whose dst lies in [s*N/S, (s+1)*N/S), padded + masked;
#   * inside one shard_map over the whole forward: per layer, all_gather the
#     [N/S, d] node block (the ONLY collective), gather sources locally,
#     segment_sum into the LOCAL dst range (no all-reduce), run the MLP on
#     the local node block (no redundant compute);
#   * the loss is a local masked CE + psum.

def _all_axes(mesh) -> tuple:
    return tuple(a for a in ("pod", "data", "model") if a in mesh.axis_names)


def forward_dst_sharded(params, feats_loc, edges_loc, edge_mask_loc, cfg: GINConfig,
                        axes: tuple, n_shards: int):
    """Body run per shard: feats_loc [N/S, d]; edges_loc [2, E/S] (dst local)."""
    n_loc = feats_loc.shape[0]
    shard = jax.lax.axis_index(axes[0])
    for ax in axes[1:]:
        shard = shard * jax.lax.axis_size(ax) + jax.lax.axis_index(ax)
    dst_off = shard * n_loc
    h_loc = feats_loc
    src, dst = edges_loc[0], edges_loc[1]
    mdt = jnp.bfloat16 if cfg.message_dtype == "bfloat16" else jnp.float32
    for lp in params["layers"]:
        # the ONLY collective: gather node blocks in message_dtype (bf16
        # halves the wire); segment accumulation stays f32
        h_full = jax.lax.all_gather(h_loc.astype(mdt), axes, tiled=True)
        msg = h_full[src].astype(jnp.float32) * edge_mask_loc[:, None]
        agg = jax.ops.segment_sum(msg, dst - dst_off, num_segments=n_loc)
        z = (1.0 + lp["eps"]) * h_loc + agg
        z = jax.nn.relu(z @ lp["w1"] + lp["b1"])
        z = z @ lp["w2"] + lp["b2"]
        h_loc = layer_norm(z, lp["ln_scale"], lp["ln_bias"])
    return h_loc @ params["head"] + params["head_b"]


def loss_fn_dst_sharded(params, batch, cfg: GINConfig, mesh=None):
    """batch: feats [N,d], edges [2,E] dst-grouped, edge_mask, labels,
    label_mask -- all sharded over every mesh axis (see batch_specs_sharded)."""
    from repro.compat import get_abstract_mesh

    mesh = mesh or get_abstract_mesh()
    if mesh is None or not mesh.axis_names:
        return loss_fn(params, batch, cfg)
    axes = _all_axes(mesh)
    S = 1
    for a in axes:
        S *= mesh.shape[a]

    def body(feats, edges, emask, labels, lmask, params):
        logits = forward_dst_sharded(params, feats, edges, emask, cfg, axes, S)
        logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        nll = -jnp.take_along_axis(logp, labels[:, None], axis=-1)[:, 0]
        m = lmask.astype(jnp.float32)
        num = jax.lax.psum((nll * m).sum(), axes)
        den = jax.lax.psum(m.sum(), axes)
        return num / jnp.maximum(den, 1.0)

    pspec = jax.tree_util.tree_map(lambda _: P(), params)
    return jax.shard_map(
        body,
        mesh=mesh,
        in_specs=(P(axes, None), P(None, axes), P(axes), P(axes), P(axes), pspec),
        out_specs=P(),
        check_vma=False,
    )(batch["feats"], batch["edges"], batch["edge_mask"], batch["labels"],
      batch["label_mask"], params)


def batch_specs_sharded(cfg: GINConfig, axes=("pod", "data", "model")):
    return {
        "feats": P(axes, None),
        "edges": P(None, axes),
        "edge_mask": P(axes),
        "labels": P(axes),
        "label_mask": P(axes),
    }


def group_edges_by_dst_shard(edges: "np.ndarray", n_nodes: int, n_shards: int):
    """Host-side layout pass: group (+pad) edges so slice s holds only edges
    with dst in shard s's node range.  Returns (edges [2, S*E_loc], mask)."""
    import numpy as np

    n_loc = n_nodes // n_shards
    owner = np.minimum(edges[1] // n_loc, n_shards - 1)
    counts = np.bincount(owner, minlength=n_shards)
    e_loc = int(counts.max()) if counts.size else 1
    out = np.zeros((2, n_shards * e_loc), edges.dtype)
    mask = np.zeros(n_shards * e_loc, bool)
    for s in range(n_shards):
        sel = np.flatnonzero(owner == s)
        out[:, s * e_loc : s * e_loc + sel.size] = edges[:, sel]
        # padding edges self-loop into the local range so indices stay local
        out[1, s * e_loc + sel.size : (s + 1) * e_loc] = s * n_loc
        mask[s * e_loc : s * e_loc + sel.size] = True
    return out, mask, e_loc


def input_specs(cfg: GINConfig, n_nodes: int, n_edges: int, n_graphs: int = 0):
    """ShapeDtypeStructs for the dry-run (shapes pre-padded by caller)."""
    spec = {
        "feats": jax.ShapeDtypeStruct((n_nodes, cfg.d_in), jnp.float32),
        "edges": jax.ShapeDtypeStruct((2, n_edges), jnp.int32),
        "edge_mask": jax.ShapeDtypeStruct((n_edges,), jnp.bool_),
    }
    if cfg.graph_readout:
        spec["graph_ids"] = jax.ShapeDtypeStruct((n_nodes,), jnp.int32)
        spec["labels"] = jax.ShapeDtypeStruct((n_graphs,), jnp.int32)
    else:
        spec["labels"] = jax.ShapeDtypeStruct((n_nodes,), jnp.int32)
        spec["label_mask"] = jax.ShapeDtypeStruct((n_nodes,), jnp.bool_)
    return spec


def batch_specs(cfg: GINConfig, data_axes=("pod", "data")):
    """PartitionSpecs: edges sharded over data axes, nodes replicated."""
    d = data_axes
    spec = {
        "feats": P(),
        "edges": P(None, d),
        "edge_mask": P(d),
    }
    if cfg.graph_readout:
        spec["graph_ids"] = P()
        spec["labels"] = P()
    else:
        spec["labels"] = P()
        spec["label_mask"] = P()
    return spec
