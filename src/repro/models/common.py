"""Shared model utilities: norms, initializers, param-tree helpers."""

from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(dt)


def layer_norm(x: jnp.ndarray, scale: jnp.ndarray, bias: jnp.ndarray, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dt)


def dense_init(key, shape, scale: float | None = None, dtype=jnp.float32):
    """Truncated-normal fan-in init."""
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    std = scale if scale is not None else 1.0 / math.sqrt(fan_in)
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32) * std).astype(dtype)


def split_keys(key, names):
    keys = jax.random.split(key, len(names))
    return dict(zip(names, keys))


def tree_size(tree: Any) -> int:
    return sum(int(np.prod(x.shape)) for x in jax.tree_util.tree_leaves(tree))


def tree_bytes(tree: Any) -> int:
    return sum(
        int(np.prod(x.shape)) * x.dtype.itemsize for x in jax.tree_util.tree_leaves(tree)
    )


def cast_tree(tree: Any, dtype) -> Any:
    return jax.tree_util.tree_map(
        lambda x: x.astype(dtype) if jnp.issubdtype(x.dtype, jnp.floating) else x, tree
    )
