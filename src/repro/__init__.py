"""repro: reproduction of "On Optimally Partitioning Variable-Byte Codes"
grown into a jax/pallas serving system.

Importing any ``repro.*`` module first runs this package init, which installs
the jax version-compat backfills (see ``repro.compat``) so the rest of the
codebase can target one jax API surface.
"""

from . import compat  # noqa: F401  (side effect: jax API backfills)
