"""Unified engine-construction facade (DESIGN.md §14).

Engine construction had grown a kwarg sprawl: every caller (the serving
loop, six benchmark drivers, the resilience layer, tests) threaded its own
subset of ``backend= / shards= / cache_bytes= / resident= / ...`` through
``QueryEngine`` and ``TopKEngine``, and new engine options meant touching
every call site.  ``EngineConfig`` is the one frozen record of every
engine option; ``make_query_engine`` / ``make_topk_engine`` build the
engines from it, and the engines themselves accept ``config=`` directly.

Legacy keywords keep working -- ``QueryEngine(idx, backend="ref")`` is
untouched -- through one coercion point (``coerce_config``): keywords
alone are silently lifted into a config; a keyword that CONFLICTS with an
explicit ``config=`` wins but emits a ``DeprecationWarning`` (the two
sources disagree, and the keyword path is the deprecated one); an unknown
keyword raises ``TypeError`` naming this module (previously ``TopKEngine``
silently ignored typos).

``EngineConfig`` round-trips JSON (``to_json`` / ``from_json``) for config
files (``serve.py --config``), and ``from_args`` lifts an ``argparse``
namespace -- the serving flags map 1:1 onto fields.  ``fault_injector``
is a live object and is deliberately NOT serializable: ``to_json`` raises
if one is set.
"""

from __future__ import annotations

import dataclasses
import json
import warnings
from dataclasses import dataclass

#: sentinel distinguishing "caller passed this keyword" from "default"
UNSET = type("_Unset", (), {"__repr__": lambda s: "UNSET"})()

CODEC_POLICIES = ("svb", "auto", "ef")


@dataclass(frozen=True)
class EngineConfig:
    """Every engine-construction option, in one frozen record.

    Fields not meaningful to an engine are ignored by it (``resident`` by
    ``QueryEngine``; ``fused`` / ``group`` / the cache bounds by
    ``TopKEngine``) -- one config can build both engines of a serving
    process.
    """

    backend: str = "auto"          # "auto" | "numpy" | "ref" | "pallas"
    fused: bool = True             # QueryEngine: fused locate->decode path
    group: bool = True             # QueryEngine: group duplicate cursors
    resident: str = "auto"         # TopKEngine: "auto" | "mirror" | "kernel"
    codec_policy: str = "auto"     # arena codec: "svb" | "auto" | "ef"
    shards: int | None = None      # list-hash shard count (None = unsharded)
    shard_mesh: object = "auto"    # "auto" | None | a Mesh with "shard" axis
    replicas: int = 1              # replica placement factor (R <= S)
    cache_parts: int = 32_768      # QueryEngine LRU entry bound
    cache_bytes: int = 256 << 20   # QueryEngine LRU/mirror byte budget
    fault_injector: object = None  # live ShardFaultInjector (not serialized)

    def __post_init__(self):
        if self.codec_policy not in CODEC_POLICIES:
            raise ValueError(
                f"codec_policy must be one of {CODEC_POLICIES}, got "
                f"{self.codec_policy!r}"
            )

    def replace(self, **updates) -> "EngineConfig":
        """A copy with the given fields replaced (frozen-dataclass update)."""
        return dataclasses.replace(self, **updates)

    # ------------------------------------------------------------------
    # JSON round-trip (serve.py --config files)
    # ------------------------------------------------------------------
    def to_json(self) -> str:
        if self.fault_injector is not None:
            raise ValueError(
                "fault_injector is a live object and cannot be serialized; "
                "clear it (cfg.replace(fault_injector=None)) before to_json()"
            )
        if self.shard_mesh not in ("auto", None):
            raise ValueError(
                "an explicit shard_mesh (a Mesh object) cannot be "
                "serialized; use 'auto' or None in serialized configs"
            )
        d = dataclasses.asdict(self)
        del d["fault_injector"]
        return json.dumps(d, indent=2, sort_keys=True)

    @classmethod
    def from_json(cls, text: str) -> "EngineConfig":
        d = json.loads(text)
        unknown = set(d) - {f.name for f in dataclasses.fields(cls)}
        if unknown:
            raise ValueError(
                f"unknown EngineConfig field(s) in JSON: {sorted(unknown)}"
            )
        if "fault_injector" in d:
            raise ValueError("fault_injector cannot come from JSON")
        return cls(**d)

    @classmethod
    def from_args(cls, ns) -> "EngineConfig":
        """Lift an argparse namespace (``launch.serve`` flags) into a config.

        A ``--config FILE`` JSON (``ns.config``) supplies the base; any
        recognized flag present on the namespace overrides its field.
        ``--codec`` maps to ``codec_policy``.
        """
        base = cls()
        path = getattr(ns, "config", None)
        if path:
            with open(path) as fh:
                base = cls.from_json(fh.read())
        updates = {}
        for name in (
            "backend", "fused", "group", "resident", "shards", "shard_mesh",
            "replicas", "cache_parts", "cache_bytes",
        ):
            val = getattr(ns, name, None)
            if val is not None:
                updates[name] = val
        codec = getattr(ns, "codec", None)
        if codec is not None:
            updates["codec_policy"] = codec
        return base.replace(**updates) if updates else base


def coerce_config(engine: str, config, explicit: dict, extra: dict):
    """Resolve ``config=`` plus legacy keywords into one ``EngineConfig``.

    THE compatibility point the engines call from ``__init__``: ``explicit``
    maps each legacy keyword to its passed value (``UNSET`` when the caller
    left it alone); ``extra`` holds unrecognized ``**kwargs``.  Keywords
    alone lift silently; a keyword disagreeing with an explicit config wins
    with a ``DeprecationWarning``; unknown keywords raise ``TypeError``.
    """
    if extra:
        bad = ", ".join(sorted(extra))
        raise TypeError(
            f"{engine} got unexpected keyword argument(s): {bad}. Engine "
            "options are the fields of repro.api.EngineConfig -- pass "
            "config=EngineConfig(...) or one of its field names as a "
            "keyword."
        )
    cfg = config if config is not None else EngineConfig()
    updates = {}
    for name, val in explicit.items():
        if val is UNSET:
            continue
        if config is not None and val != getattr(cfg, name):
            warnings.warn(
                f"{engine}: keyword {name}={val!r} overrides "
                f"config.{name}={getattr(cfg, name)!r}; passing both is "
                "deprecated -- put the value in the EngineConfig",
                DeprecationWarning,
                stacklevel=3,
            )
        updates[name] = val
    return cfg.replace(**updates) if updates else cfg


def make_query_engine(index, config: EngineConfig | None = None):
    """Boolean/NextGEQ engine over ``index`` from one ``EngineConfig``."""
    from repro.core.query_engine import QueryEngine

    return QueryEngine(index, config=config or EngineConfig())


def make_topk_engine(index, config: EngineConfig | None = None, **kwargs):
    """BM25 top-k engine over ``index`` from one ``EngineConfig``.

    ``kwargs`` passes through non-config engine knobs (``seed_blocks``).
    """
    from repro.ranked.topk_engine import TopKEngine

    return TopKEngine(index, config=config or EngineConfig(), **kwargs)
