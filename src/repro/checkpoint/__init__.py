from .manager import CheckpointManager, pack_sorted_int_array, unpack_sorted_int_array
