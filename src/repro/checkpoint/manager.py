"""Fault-tolerant checkpointing.

Features required for 1000+-node runnability:
  * atomic writes (tmp file + rename) -- a killed host never corrupts the
    latest checkpoint;
  * retention of the last ``keep`` checkpoints;
  * async save (background thread) so the train loop is not blocked;
  * restore-to-new-mesh: leaves are stored logically (full arrays); on load
    they are ``jax.device_put`` with the *target* sharding, so a job may
    restart on a different mesh shape (elastic scaling);
  * integer arrays that are strictly increasing (data-pipeline shard
    indices, CSR adjacency, sample orders) are stored OptVB-packed with the
    paper's optimal partitioning -- the framework's own codec (DESIGN.md
    section 4.3).
"""

from __future__ import annotations

import json
import pathlib
import shutil
import sys
import threading
import time
import zipfile

import numpy as np

import jax

from repro import obs
from repro.core import build_partitioned_index
from repro.core.costs import gaps_from_sorted
from repro.core.index import PartitionedIndex


# --------------------------------------------------------------------------
# OptVB packing of sorted integer arrays
# --------------------------------------------------------------------------

def pack_sorted_int_array(arr: np.ndarray) -> dict:
    """Pack a strictly-increasing int array with the paper's codec."""
    idx = build_partitioned_index([np.asarray(arr, dtype=np.int64)], "optimal")
    return {
        "kind": "optvb",
        "n": int(arr.size),
        "endpoints": idx.endpoints,
        "sizes": idx.sizes,
        "tags": idx.tags,
        "offsets": idx.offsets,
        "payload": idx.payload,
        "list_part_offsets": idx.list_part_offsets,
        "list_sizes": idx.list_sizes,
    }


def unpack_sorted_int_array(packed: dict) -> np.ndarray:
    idx = PartitionedIndex(
        n_lists=1,
        list_part_offsets=packed["list_part_offsets"],
        list_sizes=packed["list_sizes"],
        endpoints=packed["endpoints"],
        sizes=packed["sizes"],
        tags=packed["tags"],
        offsets=packed["offsets"],
        payload=packed["payload"],
    )
    return idx.decode_list(0)


def _is_strictly_increasing(a: np.ndarray) -> bool:
    return a.ndim == 1 and a.size > 1 and bool(np.all(a[1:] > a[:-1]))


# everything a corrupt/truncated checkpoint can throw at restore time: bad
# zip central directory (truncated npz), short member payload or shape
# mismatch (ValueError), missing npz keys (KeyError), unreadable files
# (OSError), bad JSON (json.JSONDecodeError is a ValueError)
RESTORE_ERRORS = (OSError, ValueError, KeyError, zipfile.BadZipFile)


# --------------------------------------------------------------------------
# Manager
# --------------------------------------------------------------------------

class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, async_save: bool = True):
        self.dir = pathlib.Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self.async_save = async_save
        self._thread: threading.Thread | None = None

    # ---------------- save ----------------
    def save(self, step: int, tree) -> None:
        host_tree = jax.tree_util.tree_map(np.asarray, jax.device_get(tree))
        if self.async_save:
            self.wait()
            self._thread = threading.Thread(
                target=self._save_sync, args=(step, host_tree), daemon=True
            )
            self._thread.start()
        else:
            self._save_sync(step, host_tree)

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _save_sync(self, step: int, host_tree) -> None:
        with obs.timer("checkpoint_save_ms"):
            leaves, treedef = jax.tree_util.tree_flatten(host_tree)
            arrays = {}
            manifest = {"step": step, "treedef": str(treedef), "leaves": []}
            for i, leaf in enumerate(leaves):
                leaf = np.asarray(leaf)
                entry = {"i": i, "dtype": str(leaf.dtype), "shape": list(leaf.shape)}
                if leaf.dtype.kind in "iu" and _is_strictly_increasing(leaf):
                    packed = pack_sorted_int_array(leaf)
                    entry["codec"] = "optvb"
                    for k, v in packed.items():
                        if isinstance(v, np.ndarray):
                            arrays[f"l{i}_{k}"] = v
                        else:
                            entry[k] = v
                else:
                    entry["codec"] = "raw"
                    arrays[f"l{i}"] = leaf
                manifest["leaves"].append(entry)

            tmp = self.dir / f".tmp-{step}-{time.time_ns()}"
            tmp.mkdir()
            np.savez(tmp / "arrays.npz", **arrays)
            (tmp / "manifest.json").write_text(json.dumps(manifest))
            final = self.dir / f"step_{step:010d}"
            if final.exists():
                shutil.rmtree(final)
            tmp.rename(final)  # atomic publish
            self._gc()
        if obs.enabled():
            obs.count(
                "checkpoint_saved_bytes",
                sum(a.nbytes for a in arrays.values()),
            )
            obs.count("checkpoint_saves")

    def _gc(self) -> None:
        ckpts = sorted(self.dir.glob("step_*"))
        for old in ckpts[: -self.keep]:
            shutil.rmtree(old, ignore_errors=True)

    # ---------------- restore ----------------
    def steps(self) -> list[int]:
        """All retained checkpoint steps, ascending."""
        return sorted(int(p.name.split("_")[1]) for p in self.dir.glob("step_*"))

    def latest_step(self) -> int | None:
        steps = self.steps()
        return steps[-1] if steps else None

    def manifest(self, step: int) -> dict:
        """Parsed manifest of one retained step (raises if unreadable)."""
        path = self.dir / f"step_{step:010d}" / "manifest.json"
        return json.loads(path.read_text())

    def restore(self, target_tree, step: int | None = None, shardings=None):
        """Load into the structure of ``target_tree``.

        ``shardings``: optional pytree of Sharding -- enables restore onto a
        different mesh than the checkpoint was written from (elastic).

        With ``step=None`` a corrupt or truncated newest checkpoint (bad
        JSON, short zip payload, missing members) is SKIPPED with a warning
        and the newest *intact* retained step restores instead -- a
        half-written checkpoint from a crashed host must degrade recovery
        by one save interval, not kill it.  An explicit ``step`` never
        falls back: the caller asked for that exact state.
        """
        if step is not None:
            return self._restore_step(target_tree, step, shardings)
        steps = self.steps()
        if not steps:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        last_err: Exception | None = None
        for s in reversed(steps):
            try:
                return self._restore_step(target_tree, s, shardings)
            except RESTORE_ERRORS as e:
                print(
                    f"[ckpt] step {s} unreadable ({type(e).__name__}: {e}); "
                    "falling back to the previous retained step",
                    file=sys.stderr,
                )
                last_err = e
        raise FileNotFoundError(
            f"no intact checkpoint in {self.dir}"
        ) from last_err

    def _restore_step(self, target_tree, step: int, shardings=None):
        path = self.dir / f"step_{step:010d}"
        nbytes = 0
        with obs.timer("checkpoint_restore_ms"):
            manifest = json.loads((path / "manifest.json").read_text())
            data = np.load(path / "arrays.npz")
            leaves_t, treedef = jax.tree_util.tree_flatten(target_tree)
            out = []
            for entry, tgt in zip(manifest["leaves"], leaves_t):
                i = entry["i"]
                if entry["codec"] == "optvb":
                    packed = {k: data[f"l{i}_{k}"] for k in
                              ("endpoints", "sizes", "tags", "offsets", "payload",
                               "list_part_offsets", "list_sizes")}
                    arr = unpack_sorted_int_array(packed).astype(entry["dtype"])
                else:
                    arr = data[f"l{i}"]
                nbytes += arr.nbytes
                out.append(arr.reshape(entry["shape"]))
            tree = jax.tree_util.tree_unflatten(treedef, out)
            if shardings is not None:
                tree = jax.tree_util.tree_map(
                    lambda a, s: jax.device_put(a, s), tree, shardings
                )
        if obs.enabled():
            obs.count("checkpoint_restored_bytes", nbytes)
            obs.count("checkpoint_restores")
        return tree, step
