"""AdamW + gradient clipping + LR schedules (pure pytree functions).

State layout mirrors the param tree ({m, v} per leaf + scalar count), so the
same PartitionSpec tree shards optimizer state exactly like the params.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def adamw_init(params):
    zeros = jax.tree_util.tree_map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return {
        "m": zeros,
        "v": jax.tree_util.tree_map(lambda z: z.copy(), zeros),
        "count": jnp.zeros((), jnp.int32),
    }


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree_util.tree_leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree_util.tree_map(lambda g: g * scale.astype(g.dtype), grads), gn


def adamw_update(
    grads,
    state,
    params,
    lr,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.01,
):
    count = state["count"] + 1
    c1 = 1.0 - b1 ** count.astype(jnp.float32)
    c2 = 1.0 - b2 ** count.astype(jnp.float32)

    def upd(g, m, v, p):
        g = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        step = (m / c1) / (jnp.sqrt(v / c2) + eps)
        newp = p.astype(jnp.float32) - lr * (step + weight_decay * p.astype(jnp.float32))
        return newp.astype(p.dtype), m, v

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    flat_p = treedef.flatten_up_to(params)
    out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "count": count}


def cosine_lr(step, base_lr: float, warmup: int, total: int, min_ratio: float = 0.1):
    step = step.astype(jnp.float32)
    warm = base_lr * step / jnp.maximum(warmup, 1)
    prog = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
    cos = base_lr * (min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
    return jnp.where(step < warmup, warm, cos)
