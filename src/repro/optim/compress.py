"""Error-feedback int8 gradient compression for data-parallel all-reduce.

A distributed-optimization option for collective-bound training cells: the
data-axis gradient all-reduce runs on int8-quantized tensors (4x fewer wire
bytes than f32) with per-tensor scales; the quantization error is carried to
the next step (error feedback, Seide et al. / EF-SGD), preserving
convergence.  Implemented with shard_map + psum so the wire format is
explicit, not an XLA choice.

Usage (see tests/test_compress.py):
    state = ef_init(grads_shape)
    grads_sync, state = compressed_psum(grads_local, state, mesh, ("data",))
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def ef_init(params):
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params
    )


def _quantize(x):
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def compressed_psum(grads, ef_state, mesh, axes=("data",)):
    """All-reduce ``grads`` over ``axes`` in int8 with error feedback.

    grads/ef_state: pytrees of f32 arrays REPLICATED over ``axes`` is wrong --
    each shard passes its LOCAL gradient contribution; returns the averaged
    gradient + updated error-feedback residuals.
    """
    n = 1
    for a in axes:
        n *= mesh.shape[a]

    def body(g, e):
        def one(gl, el):
            x = gl + el
            q, scale = _quantize(x)
            err = x - q.astype(jnp.float32) * scale
            qsum = jax.lax.psum(q.astype(jnp.int32), axes)
            ssum = jax.lax.psum(scale, axes)  # scalar; scales averaged
            g_sync = qsum.astype(jnp.float32) * (ssum / n) / n
            return g_sync, err

        flat_g, tree = jax.tree_util.tree_flatten(g)
        flat_e = tree.flatten_up_to(e)
        out = [one(a, b) for a, b in zip(flat_g, flat_e)]
        return (
            tree.unflatten([o[0] for o in out]),
            tree.unflatten([o[1] for o in out]),
        )

    spec = jax.tree_util.tree_map(lambda _: P(), grads)
    return jax.shard_map(
        body,
        mesh=mesh,
        in_specs=(spec, spec),
        out_specs=(spec, spec),
        check_vma=False,
    )(grads, ef_state)
